"""Fault-injecting training harness for real sharded JAX training.

Closes the loop between the simulated recovery policies (PRs 1–5 score
``CheckpointRestore`` vs ``PeerTakeover`` inside the discrete-event
runtime) and what real sharded training actually survives: a
transformer config trains on an FSDP-style host-device mesh under a
deterministic :class:`~repro.resilience.schedule.FaultSchedule`; at a
scheduled step a data-parallel worker is lost mid-step, and the run
recovers through the *same policy objects* the event runtime scores,
via their ``real_apply`` hooks (``repro.serverless.recovery``):

  CheckpointRestore  the λML / MLLess model: the supervisor re-invokes
      the lost worker (the rebuilt full-width mesh), rolls the fleet
      back to the last mid-epoch ``repro.checkpoint`` snapshot and
      *replays* the lost steps.  With deterministic data the replayed
      trace is bit-identical to the uninterrupted same-seed run —
      the harness records the overlap for the regression tests.  With
      ``restore_reinvoke=False`` the snapshot restores onto the
      *shrunk survivor mesh* instead (sharded restore onto a different
      mesh; survivors then replay and absorb the dead partition).

  PeerTakeover  SPIRT (arXiv 2309.14148): per-worker state partitions
      live in the in-memory "in-DB" store
      (:class:`~repro.resilience.store.InMemoryStore`), pushed every
      ``push_every`` steps.  Survivors reassemble the current state
      from the store's bytes — the dead peer's partition is the one
      transfer recovery buys — re-shard it onto the survivor mesh
      (``sharding.survivor_mesh``) and continue *without replay*,
      absorbing the dead worker's minibatches.

Wall-clock accounting: both survivor-width and full-width step
functions are compiled during setup (``_warm``), so recovery wall times
measure state movement + replay — not XLA compilation, which is an
artifact of the single-process stand-in (a real SPIRT fleet's survivors
are warm processes, and a re-invoked Lambda's cold start is priced
separately by the event runtime's cold-start terms).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.resilience.schedule import FaultSchedule
from repro.resilience.store import InMemoryStore


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """One resilient-training scenario (pure data, eagerly validated).

    ``arch`` names a ``repro.configs`` model (transformer family);
    ``sim_arch`` names the serverless :class:`~repro.serverless.archs.
    ArchSpec` twin — the harness trains with that spec's real-JAX
    strategy (``spec.make_strategy()``), so the simulated scenario and
    the real run share one architecture definition."""
    arch: str = "smollm-135m"
    sim_arch: str = "spirt"
    n_workers: int = 4
    steps: int = 12
    global_batch: int = 12
    seq: int = 16
    lr: float = 1e-2
    checkpoint_every: int = 4
    push_every: int = 1
    fsdp: bool = True
    reduced: bool = True
    restore_reinvoke: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.n_workers < 2:
            raise ValueError(
                f"n_workers must be >= 2 (a one-worker fleet has no "
                f"survivors), got {self.n_workers}")
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.checkpoint_every < 1 or self.push_every < 1:
            raise ValueError(
                f"checkpoint_every/push_every must be >= 1, got "
                f"{self.checkpoint_every}/{self.push_every}")
        if self.global_batch % self.n_workers:
            raise ValueError(
                f"global_batch {self.global_batch} must divide over "
                f"{self.n_workers} workers")
        if self.global_batch % (self.n_workers - 1):
            raise ValueError(
                f"global_batch {self.global_batch} must also divide "
                f"over {self.n_workers - 1} survivors (takeover "
                f"re-shards the same batch onto the shrunk fleet)")
        if self.seq < 2:
            raise ValueError(f"seq must be >= 2, got {self.seq}")


@dataclasses.dataclass
class RecoveryOutcome:
    """What one real recovery cost (one row of BENCH_recovery.json)."""
    step: int                       # kill step (in-flight work lost)
    worker: int
    mode: str                       # "restore" | "takeover"
    replayed_steps: int             # steps re-run from the snapshot
    wall_s: float                   # state movement + replay
    bytes_moved: int                # ckpt read | dead partition fetched
    n_workers_after: int
    ckpt_step: Optional[int] = None  # restore: snapshot rolled back to


@dataclasses.dataclass
class RunResult:
    """One training run (faulted or not) of the harness."""
    arch: str
    sim_arch: str
    losses: Tuple[float, ...]
    recoveries: List[RecoveryOutcome]
    n_params: int
    state_bytes: int                # serialized full-state blob size
    step_s: float                   # median fault-free step wall time
    n_workers_end: int
    replay_checks: Tuple[Tuple[int, float, float], ...] = ()
    # ^ (step, loss before kill, loss re-computed during replay)

    @property
    def final_loss(self) -> float:
        return self.losses[-1]

    @property
    def replay_exact(self) -> bool:
        """Every replayed step reproduced its pre-kill loss bit-exactly
        (vacuously true when nothing was replayed)."""
        return all(a == b for _, a, b in self.replay_checks)


class ResilientTrainer:
    """Drives one config through faulted/unfaulted runs.

    Construction compiles nothing; :meth:`run` owns the whole lifecycle
    (fresh state, fresh store, fresh checkpoint directory) so repeated
    calls with equal seeds replay bit-identically.
    """

    def __init__(self, config: ResilienceConfig,
                 ckpt_dir: Optional[str] = None):
        import jax

        from repro import optim
        from repro.configs.base import get_config
        from repro.data import lm_batches, token_stream
        from repro.models import build_model
        from repro.serverless.archs import get_arch

        self.config = config
        mcfg = get_config(config.arch)
        if config.reduced:
            mcfg = mcfg.reduced()
        if mcfg.family == "cnn":
            raise ValueError(
                f"{config.arch!r} is a CNN; the resilience harness "
                "targets the sharded transformer configs")
        self.model_config = mcfg
        self.model = build_model(mcfg, remat=False)
        self.optimizer = optim.adamw(config.lr)
        self.strategy = get_arch(config.sim_arch).make_strategy()
        devices = jax.devices()
        if len(devices) < config.n_workers:
            raise RuntimeError(
                f"need {config.n_workers} devices, have {len(devices)} "
                "(run under --xla_force_host_platform_device_count)")
        self._all_devices = tuple(devices[:config.n_workers])
        self._ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="resil_")

        # deterministic per-step batches: a pure function of
        # (config.seed, step) — replay after restore re-reads the same
        # minibatches the lost steps consumed
        stream = token_stream(
            max(config.global_batch, 64) * (config.seq + 1) * 8,
            mcfg.vocab_size, seed=config.seed)
        it = lm_batches(stream, config.global_batch, config.seq,
                        seed=config.seed)
        self._batches = [next(it) for _ in range(config.steps)]

        # run-scoped state (set up by run())
        self.store = InMemoryStore()
        self._ts_cache: Dict[int, Any] = {}
        self._mesh = self._ts = self._state = None
        self._devices: Tuple = ()
        self._completed = 0
        self._losses: List[float] = []
        self._ckpt_steps: Dict[int, str] = {}
        self._replay_checks: List[Tuple[int, float, float]] = []

    # ------------------------------------------------------------------
    # mesh / step plumbing
    # ------------------------------------------------------------------
    def _build(self, devices):
        """(mesh, TrainStep) for a device tuple — FSDP-style: a pure
        data-parallel axis plus a width-1 'model' axis; param/optimizer
        leaves shard over 'data' where divisible (picodo idiom)."""
        import jax

        from repro.core import build_train_step
        mesh = jax.sharding.Mesh(
            np.asarray(devices).reshape(len(devices), 1),
            ("data", "model"))
        ts = build_train_step(self.model, self.optimizer, self.strategy,
                              mesh, fsdp=self.config.fsdp)
        return mesh, ts

    def _get_ts(self, devices):
        key = len(devices)
        if key not in self._ts_cache:
            self._ts_cache[key] = self._build(devices)
        return self._ts_cache[key]

    def _warm(self, devices):
        """Compile the step for this fleet width on throwaway state so
        recovery wall times exclude XLA compilation (see module doc)."""
        import jax
        _, ts = self._get_ts(devices)
        state = ts.init_state(jax.random.PRNGKey(0))
        ts.step_fn(state, self._put_batch(0, ts))

    def _put_batch(self, step, ts):
        import jax
        import jax.numpy as jnp
        return {k: jax.device_put(jnp.asarray(v), ts.batch_shardings[k])
                for k, v in self._batches[step].items()}

    def _do_step(self, step) -> float:
        self._state, m = self._ts.step_fn(
            self._state, self._put_batch(step, self._ts))
        return float(m["loss"])

    # ------------------------------------------------------------------
    # snapshots (checkpoint cadence + in-DB partitions)
    # ------------------------------------------------------------------
    def _snapshot(self):
        """Persist the current state: a mid-epoch checkpoint file every
        ``checkpoint_every`` completed steps (restore path) and the
        partitioned in-DB blob every ``push_every`` (takeover path)."""
        from repro import checkpoint
        c = self._completed
        if c % self.config.push_every == 0 or c == 0:
            self.store.push_partitions(checkpoint.dumps(self._state),
                                       len(self._devices))
        if c % self.config.checkpoint_every == 0:
            path = os.path.join(self._ckpt_dir, f"step_{c:06d}.msgpack")
            checkpoint.save(path, self._state)
            self._ckpt_steps[c] = path

    def _state_host(self) -> Any:
        """Current state as host numpy arrays (global view)."""
        import jax
        return jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                            self._state)

    def _adopt(self, host_state, mesh, ts, dead: Optional[int]):
        """Re-shard a host-side global state onto ``mesh`` via ``ts``'s
        shardings.  ``dead`` (takeover / shrunk restore) drops that
        worker's row from the per-worker strategy state — the survivors
        keep theirs, the dead peer's transient sync state is lost with
        it (SPIRT keeps durable state in the DB, which we restored)."""
        import jax
        import jax.numpy as jnp

        strat = host_state["strat"]
        if dead is not None:
            strat = jax.tree.map(lambda x: np.delete(x, dead, axis=0),
                                 strat)
        host_state = dict(host_state, strat=strat)
        self._mesh, self._ts = mesh, ts
        sds = ts.state_sds()
        self._state = jax.tree.map(
            lambda x, ref: jax.device_put(
                np.asarray(x), ref.sharding) if ref.sharding is not None
            else jnp.asarray(x),
            host_state, sds)

    # ------------------------------------------------------------------
    # recovery paths (driven by RecoveryPolicy.real_apply)
    # ------------------------------------------------------------------
    def recover_restore(self, worker: int) -> RecoveryOutcome:
        """Roll back to the last checkpoint and replay the lost steps.

        ``restore_reinvoke=True`` (default, the simulator's
        CheckpointRestore semantics): the dead worker is re-invoked, the
        full-width mesh is rebuilt, and the snapshot restores onto it —
        the replayed + continued trace is bit-identical to the
        uninterrupted same-seed run.  ``False``: the snapshot restores
        onto the *shrunk survivor mesh* (a genuinely different mesh than
        it was written from) and survivors replay, absorbing the dead
        partition — convergent, but not bit-comparable across widths.
        """
        from repro import checkpoint
        t0 = time.perf_counter()  # repro: allow[no-wallclock] -- measured recovery wall time is this harness's deliverable
        completed = self._completed
        ckpt_step = max(s for s in self._ckpt_steps if s <= completed)
        path = self._ckpt_steps[ckpt_step]
        replay = completed - ckpt_step

        if self.config.restore_reinvoke:
            devices = self._devices          # replacement fills the slot
            mesh, ts = self._get_ts(devices)
            # sharded restore straight onto the step's shardings: the
            # SDS template allocates nothing
            state = checkpoint.restore(path, like=ts.state_sds())
            self._mesh, self._ts, self._state = mesh, ts, state
        else:
            devices = (self._devices[:worker]
                       + self._devices[worker + 1:])
            mesh, ts = self._get_ts(devices)
            # restore to writable host arrays, then re-shard onto the
            # survivor mesh (strategy state loses the dead row)
            host = checkpoint.restore(path, like=self._host_template())
            self._devices = devices
            self._adopt(host, mesh, ts, dead=worker)

        self._completed = ckpt_step
        for t in range(ckpt_step, completed):
            loss = self._do_step(t)
            if t < len(self._losses):
                self._replay_checks.append((t, self._losses[t], loss))
                self._losses[t] = loss
            self._completed = t + 1
        wall = time.perf_counter() - t0  # repro: allow[no-wallclock] -- measured recovery wall time is this harness's deliverable
        return RecoveryOutcome(
            step=completed, worker=worker, mode="restore",
            replayed_steps=replay, wall_s=wall,
            bytes_moved=os.path.getsize(path),
            n_workers_after=len(self._devices), ckpt_step=ckpt_step)

    def recover_takeover(self, worker: int) -> RecoveryOutcome:
        """Survivors adopt the dead peer's in-DB partition and continue
        without replay on the shrunk mesh."""
        from repro import checkpoint
        t0 = time.perf_counter()  # repro: allow[no-wallclock] -- measured recovery wall time is this harness's deliverable
        completed = self._completed
        blob, dead_bytes = self.store.fetch_state(
            len(self._devices), dead=worker)
        host = checkpoint.loads(blob, like=self._host_template())
        devices = self._devices[:worker] + self._devices[worker + 1:]
        mesh, ts = self._get_ts(devices)
        self._devices = devices
        self._adopt(host, mesh, ts, dead=worker)
        wall = time.perf_counter() - t0  # repro: allow[no-wallclock] -- measured recovery wall time is this harness's deliverable
        return RecoveryOutcome(
            step=completed, worker=worker, mode="takeover",
            replayed_steps=0, wall_s=wall, bytes_moved=dead_bytes,
            n_workers_after=len(devices))

    def _host_template(self):
        """Writable numpy zero template matching the *current* global
        state (host-side restore target before re-sharding)."""
        import jax
        return jax.tree.map(
            lambda x: np.zeros(x.shape, dtype=x.dtype), self._state)

    # ------------------------------------------------------------------
    # the training loop
    # ------------------------------------------------------------------
    def run(self, schedule: Optional[FaultSchedule] = None,
            policy=None) -> RunResult:
        """One training run under ``schedule``; ``policy`` (a
        :class:`~repro.serverless.recovery.RecoveryPolicy`) defaults to
        the ``sim_arch``'s registry default (``recovery="auto"``)."""
        import jax

        cfg = self.config
        schedule = schedule or FaultSchedule()
        if policy is None and schedule.n_kills:
            from repro.serverless.runtime import default_recovery
            policy = default_recovery(
                cfg.sim_arch, checkpoint_every=cfg.checkpoint_every)
        for step, _ in schedule.kills:
            if step >= cfg.steps:
                raise ValueError(
                    f"kill at step {step} beyond the run's "
                    f"{cfg.steps} steps")

        # fresh lifecycle
        self.store.reset()
        self._ckpt_steps = {}
        self._replay_checks = []
        self._losses = []
        self._devices = self._all_devices
        self._warm(self._all_devices)
        if schedule.n_kills:
            self._warm(self._all_devices[:-1])
        self._mesh, self._ts = self._get_ts(self._devices)
        self._state = self._ts.init_state(jax.random.PRNGKey(cfg.seed))
        self._completed = 0
        self._snapshot()                       # step-0 rollback target

        n_params = sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(self._state["params"]))
        from repro import checkpoint
        state_bytes = len(checkpoint.dumps(self._state))

        recoveries: List[RecoveryOutcome] = []
        step_walls: List[float] = []
        step = 0
        while step < cfg.steps:
            w = schedule.kill_at(step)
            if w is not None and not any(r.step == step
                                         for r in recoveries):
                # mid-step loss: step's in-flight gradient work is
                # gone; the policy decides restore vs takeover
                recoveries.append(
                    policy.real_apply(self, w % len(self._devices)))
                step = self._completed   # restore may have rolled back
                continue
            t0 = time.perf_counter()  # repro: allow[no-wallclock] -- per-step wall cost feeds the chaos report
            loss = self._do_step(step)
            step_walls.append(time.perf_counter() - t0)  # repro: allow[no-wallclock] -- per-step wall cost feeds the chaos report
            if step < len(self._losses):
                self._losses[step] = loss
            else:
                self._losses.append(loss)
            self._completed = step + 1
            self._snapshot()
            step += 1

        return RunResult(
            arch=cfg.arch, sim_arch=cfg.sim_arch,
            losses=tuple(self._losses), recoveries=recoveries,
            n_params=n_params, state_bytes=state_bytes,
            step_s=float(np.median(step_walls)),
            n_workers_end=len(self._devices),
            replay_checks=tuple(self._replay_checks))
