"""Chaos harness for real sharded training.

Runs the transformer configs on a host-device mesh under deterministic
fault schedules and recovers through the *same*
:class:`~repro.serverless.recovery.RecoveryPolicy` objects the event
runtime scores — closing the loop between simulated time-to-recover and
what checkpoint-restore vs peer-takeover actually cost on real state.
"""
from repro.resilience.harness import (RecoveryOutcome, ResilienceConfig,
                                      ResilientTrainer, RunResult)
from repro.resilience.schedule import FaultSchedule
from repro.resilience.store import InMemoryStore

__all__ = [
    "FaultSchedule",
    "InMemoryStore",
    "RecoveryOutcome",
    "ResilienceConfig",
    "ResilientTrainer",
    "RunResult",
]
