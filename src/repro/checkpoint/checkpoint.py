"""Sharding-aware msgpack checkpointing (no external deps beyond msgpack).

``dumps``/``loads`` expose the serialized form directly so state can
round-trip through in-memory channels — the resilience harness's in-DB
store (``repro.resilience.store``) partitions the same blob across
workers that ``save`` writes to disk.  ``restore``/``loads`` place
leaves onto the shardings of ``like``, which may live on a *different*
mesh than the one the checkpoint was written from: survivor re-meshing
after a worker loss (``repro.resilience``) restores a full-fleet
snapshot onto a shrunk mesh, and ``sharding.param_pspecs`` degrades any
no-longer-divisible dim to replication so the placement is always
well-defined.

Restored leaves are always *writable* (and therefore donatable): the
decoder copies each record into a fresh ``bytearray`` instead of
aliasing msgpack's read-only payload — ``np.frombuffer`` over the raw
bytes would hand back read-only arrays that a zero-copy ``device_put``
(or a numpy ``like`` template) silently propagates.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def dumps(tree: Any) -> bytes:
    """Serialize a pytree (leaves fetched to host) to one msgpack blob."""
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.asarray(l).tobytes()}
            for l in jax.device_get(leaves)
        ],
    }
    return msgpack.packb(payload, use_bin_type=True)


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(dumps(tree))
    os.replace(tmp, path)


def _decode_leaves(payload: dict) -> list:
    """Stored records -> writable host arrays (one copy per leaf via
    ``bytearray``; ``np.frombuffer`` over the msgpack bytes themselves
    would be read-only and poison every downstream zero-copy path)."""
    return [
        np.frombuffer(bytearray(rec["data"]),
                      dtype=rec["dtype"]).reshape(rec["shape"])
        for rec in payload["leaves"]
    ]


def loads(data: bytes, like: Any) -> Any:
    """Deserialize into the structure (and shardings) of ``like``.

    ``like`` leaves may be jax arrays (restored onto their sharding),
    ``jax.ShapeDtypeStruct``s (no allocation needed to describe the
    target), or plain numpy arrays (decoded host arrays are returned
    as-is — writable).  The stored treedef must match ``like``'s
    exactly: equal leaf *counts* with different structures (e.g. a
    renamed dict key) are an error, not a silent misassignment.
    """
    payload = msgpack.unpackb(data, raw=False)
    like_leaves, treedef = jax.tree.flatten(like)
    stored_def = payload["treedef"]
    if stored_def != str(treedef):
        raise ValueError(
            f"checkpoint treedef does not match the restore template:\n"
            f"  stored: {stored_def}\n"
            f"  like:   {treedef}")
    out = []
    for arr, ref in zip(_decode_leaves(payload), like_leaves):
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch {arr.shape} vs {tuple(ref.shape)}")
        sharding = getattr(ref, "sharding", None)
        if sharding is not None:
            leaf = jax.device_put(arr, sharding).astype(ref.dtype)
        elif isinstance(ref, np.ndarray):
            leaf = arr.astype(ref.dtype, copy=False)
        else:
            leaf = jnp.asarray(arr).astype(ref.dtype)
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure (and shardings) of ``like``."""
    with open(path, "rb") as f:
        return loads(f.read(), like)
