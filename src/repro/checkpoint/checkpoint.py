"""Sharding-aware msgpack checkpointing (no external deps beyond msgpack)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.asarray(l).tobytes()}
            for l in jax.device_get(leaves)
        ],
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure (and shardings) of ``like``."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    like_leaves, treedef = jax.tree.flatten(like)
    stored = payload["leaves"]
    if len(stored) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(stored)} leaves, expected "
            f"{len(like_leaves)}")
    out = []
    for rec, ref in zip(stored, like_leaves):
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"])
        if tuple(arr.shape) != tuple(np.asarray(ref).shape):
            raise ValueError(
                f"shape mismatch {arr.shape} vs {np.asarray(ref).shape}")
        dev = jax.device_put(arr, getattr(ref, "sharding", None)) \
            if hasattr(ref, "sharding") else jnp.asarray(arr)
        out.append(dev.astype(ref.dtype))
    return jax.tree.unflatten(treedef, out)
