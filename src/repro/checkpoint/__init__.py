from repro.checkpoint.checkpoint import (  # noqa: F401
    dumps, loads, restore, save,
)
