"""jax version-compat helpers.

``jax.shard_map`` became a public top-level API (with ``axis_names`` /
``check_vma`` keywords) after the 0.4.x series; the installed 0.4.37
only ships ``jax.experimental.shard_map.shard_map`` whose equivalent
knobs are ``auto`` (the *complement* of the manual axes) and
``check_rep``.  The same series also predates ``jax.lax.axis_size``
and ``jax.sharding.get_abstract_mesh``.  Every such call in the repo
goes through this module so the translation lives in exactly one place.
"""
from __future__ import annotations

from typing import Optional, Set

import jax

_NEW = getattr(jax, "shard_map", None)

# jax 0.4.x's *experimental* shard_map can express partial-manual
# meshes (auto= axes), but XLA's SPMD partitioner of that era crashes
# on them for real multi-device auto axes ("Check failed:
# sharding.IsManualSubgroup()").  Tests that need a genuinely
# partial-manual multi-device mesh skip unless the native API exists.
HAS_PARTIAL_MANUAL_SHARD_MAP = _NEW is not None


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None,
              check_vma: bool = False):
    """New-style ``jax.shard_map`` signature on any installed jax.

    ``axis_names`` are the *manual* mesh axes (``None`` => all of them);
    on old jax the remaining axes become the experimental ``auto`` set
    and ``check_vma`` maps onto ``check_rep``.
    """
    if _NEW is not None:
        kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs,
              "check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return _NEW(f, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)


def axis_size(axis_name) -> int:
    """Static size of a manual mesh axis, inside a shard_map body."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax.core import axis_frame
    return axis_frame(axis_name)            # returns the size on 0.4.x


class _EmptyMesh:
    axis_names = ()


def get_abstract_mesh():
    """Ambient abstract mesh, or an empty stand-in on old jax (callers
    treat no-axes-in-scope as 'skip the sharding hint')."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    return _EmptyMesh()
