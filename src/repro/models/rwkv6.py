"""RWKV-6 (Finch) time-mix block: linear recurrence with data-dependent
per-channel decay [arXiv:2404.05892], in chunked (GLA-style) parallel form.

Per head (head dim N):   S_t = diag(w_t) S_{t-1} + k_t^T v_t
                         y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with w_t in (0,1) data-dependent (token-shifted low-rank projection).
The chunked form computes, per chunk of length c:
  - intra-chunk: masked attention with decay ratios Lam_t / Lam_s
  - inter-chunk: state carried through a lax.scan over chunks
This is the TPU-native adaptation (MXU-friendly matmuls instead of a
length-T elementwise scan) — see DESIGN.md §5.

Decode uses the exact single-step recurrence against a (H, N, N) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    r = cfg.rwkv_lora_rank
    ks = jax.random.split(key, 10)
    return {
        "w_r": layers.dense_init(ks[0], (d, d), dtype),
        "w_k": layers.dense_init(ks[1], (d, d), dtype),
        "w_v": layers.dense_init(ks[2], (d, d), dtype),
        "w_g": layers.dense_init(ks[3], (d, d), dtype),
        "w_o": layers.dense_init(ks[4], (d, d), dtype),
        # data-dependent decay: low-rank ("lora") projection of shifted x
        "decay_a": layers.dense_init(ks[5], (d, r), dtype),
        "decay_b": layers.dense_init(ks[6], (r, d), dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),  # ~exp(-exp(-6)) ≈ slow
        "bonus_u": jnp.zeros((H, N), jnp.float32),
        # token-shift mixing coefficients
        "mix": jnp.full((5, d), 0.5, jnp.float32),
    }


def _token_shift(x, x_prev_last):
    """shift along time: out_t = x_{t-1}; position 0 uses carry."""
    prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _project(p, x, prev_last):
    """Compute r,k,v,g,w for a chunk of tokens. x: (B, T, d)."""
    xs = _token_shift(x, prev_last)
    mix = p["mix"].astype(x.dtype)
    xr = x * mix[0] + xs * (1 - mix[0])
    xk = x * mix[1] + xs * (1 - mix[1])
    xv = x * mix[2] + xs * (1 - mix[2])
    xg = x * mix[3] + xs * (1 - mix[3])
    xw = x * mix[4] + xs * (1 - mix[4])
    r = xr @ p["w_r"]
    k = xk @ p["w_k"]
    v = xv @ p["w_v"]
    g = jax.nn.silu(xg @ p["w_g"])
    # decay in (0,1): w = exp(-exp(base + lora(xw)))
    dw = (xw @ p["decay_a"]) @ p["decay_b"]
    logw = -jnp.exp(p["decay_base"].astype(jnp.float32)
                    + dw.astype(jnp.float32))          # (B,T,d) in (-inf, 0)
    return r, k, v, g, logw


def wkv_chunked_jnp(rr, kk, vv, lw, u, S0, chunk=128):
    """Pure-jnp chunked WKV core.  rr/kk/vv/lw: (B, T, H, N) fp32;
    u: (H, N); S0: (B, H, N, N).  Returns (y (B,T,H,N), S_final).

    Same math as the Pallas kernel (repro.kernels.wkv6) — this is its
    differentiable/backward form and the CPU lowering path."""
    B, T, H, N = rr.shape
    c = min(chunk, T)
    if T % c:
        T_main = (T // c) * c
        if T_main:
            y1, S0 = wkv_chunked_jnp(rr[:, :T_main], kk[:, :T_main],
                                     vv[:, :T_main], lw[:, :T_main], u,
                                     S0, chunk=c)
            y2, S0 = wkv_chunked_jnp(rr[:, T_main:], kk[:, T_main:],
                                     vv[:, T_main:], lw[:, T_main:], u,
                                     S0, chunk=T - T_main)
            return jnp.concatenate([y1, y2], axis=1), S0
        c = T
    nchunk = T // c

    def chunk_step(S, args):
        rr, kk, vv, lw = args                               # (B,c,H,N)

        # cumulative log-decay INCLUSIVE of step t: L_t = sum_{s<=t} logw_s
        L = jnp.cumsum(lw, axis=1)                          # (B,c,H,N)
        # inter-chunk: y_inter[t] = (r_t * exp(L_{t-1})) @ S_prev
        Lprev = L - lw                                      # exclusive cumsum
        q_dec = rr * jnp.exp(Lprev)
        y_inter = jnp.einsum("bthn,bhnm->bthm", q_dec, S)
        # intra-chunk: att[t,s] = sum_n r_t[n] exp(L_{t-1}-L_s)[n] k_s[n], s<t
        #   (S_{t-1} holds k_s v_s decayed by prod_{j=s+1..t-1} w_j
        #    = exp(Lprev_t - L_s), which is <= 0 in log space for s < t —
        #    so exponentiate the pairwise DIFFERENCE directly; the factored
        #    form exp(Lprev)*exp(-L) overflows under strong decay).
        diff = Lprev[:, :, None] - L[:, None, :]            # (B,t,s,H,N) <= 0
        tidx = jnp.arange(c)
        mask = tidx[:, None] > tidx[None, :]                # strict lower tri
        diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
        a = jnp.einsum("bthn,bshn,btshn->bhts", rr, kk, jnp.exp(diff))
        y_intra = jnp.einsum("bhts,bshn->bthn", a, vv)
        # bonus (current token): y += (r_t · (u ⊙ k_t)) v_t
        bonus = jnp.einsum("bthn,hn,bthn->bth", rr, u, kk)
        y_bonus = bonus[..., None] * vv
        y = y_inter + y_intra + y_bonus                     # (B,c,H,N)

        # state update: S_new = diag(exp(L_c)) S + sum_s exp(L_c - L_s) k_s v_s
        Lc = L[:, -1][:, :, :, None]                        # (B,H,N,1)
        k_dec = kk * jnp.exp(L[:, -1][:, None] - L)         # (B,c,H,N)
        S_new = jnp.exp(Lc) * S + jnp.einsum("bshn,bshm->bhnm", k_dec, vv)
        return S_new, y

    split = lambda a: a.reshape(B, nchunk, c, H, N).swapaxes(0, 1)
    S_fin, ys = jax.lax.scan(chunk_step, S0,
                             (split(rr), split(kk), split(vv), split(lw)))
    y = ys.swapaxes(0, 1).reshape(B, nchunk * c, H, N)
    return y, S_fin


def rwkv_apply(p, x, cfg, state=None, chunk=128, use_kernel=False):
    """Full-sequence (train/prefill) chunked form.

    x: (B, T, d).  state: optional dict from a previous call.
    ``use_kernel`` routes the WKV core through the Pallas kernel
    (fresh-state path only; custom-VJP backward recomputes via the jnp
    chunked form).  Returns (y, new_state).
    """
    B, T, d = x.shape
    N = cfg.rwkv_head_dim
    H = d // N
    fresh = state is None
    if state is None:
        state = rwkv_init_state(cfg, B, x.dtype)

    # token-shift over the full sequence (carry supplies position 0)
    r, k, v, g, logw = _project(p, x, state["x_last"])
    hint = lambda t: layers.shard_hint(t, None, None, "model", None)
    rr = hint(r.reshape(B, T, H, N).astype(jnp.float32))
    kk = hint(k.reshape(B, T, H, N).astype(jnp.float32))
    vv = hint(v.reshape(B, T, H, N).astype(jnp.float32))
    lw = hint(logw.reshape(B, T, H, N))
    u = p["bonus_u"].astype(jnp.float32)

    if use_kernel and fresh and T % 64 == 0:
        # Pallas WKV kernel (zero initial state); final state from a
        # single closed-form einsum: S_T = sum_s exp(L_T - L_s) k_s v_s
        y = _wkv_kernel_vjp(rr, kk, vv, lw, u)
        L = jnp.cumsum(lw, axis=1)
        k_dec = kk * jnp.exp(L[:, -1:] - L)
        S_fin = jnp.einsum("bthn,bthm->bhnm", k_dec, vv)
    else:
        y, S_fin = wkv_chunked_jnp(rr, kk, vv, lw, u, state["S"],
                                   chunk=chunk)
    y = y.reshape(B, T, d) * g.astype(jnp.float32)
    out = (y @ p["w_o"]).astype(x.dtype)
    return out, {"S": S_fin, "x_last": x[:, -1, :]}


@jax.custom_vjp
def _wkv_kernel_vjp(rr, kk, vv, lw, u):
    from repro.kernels import ops as kops
    return kops.wkv6(rr, kk, vv, lw, u)


def _wkv_fwd(rr, kk, vv, lw, u):
    return _wkv_kernel_vjp(rr, kk, vv, lw, u), (rr, kk, vv, lw, u)


def _wkv_bwd(res, gy):
    rr, kk, vv, lw, u = res
    B, T, H, N = rr.shape
    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, vjp = jax.vjp(
        lambda r_, k_, v_, l_, u_: wkv_chunked_jnp(r_, k_, v_, l_, u_,
                                                   S0)[0],
        rr, kk, vv, lw, u)
    return vjp(gy)


_wkv_kernel_vjp.defvjp(_wkv_fwd, _wkv_bwd)


def rwkv_decode_step(p, x, cfg, state):
    """Exact single-token recurrence. x: (B, 1, d)."""
    B, _, d = x.shape
    N = cfg.rwkv_head_dim
    H = d // N
    r, k, v, g, logw = _project(p, x, state["x_last"])
    rr = r.reshape(B, H, N).astype(jnp.float32)
    kk = k.reshape(B, H, N).astype(jnp.float32)
    vv = v.reshape(B, H, N).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, N))
    u = p["bonus_u"].astype(jnp.float32)
    S = state["S"]                                          # (B,H,N,N)
    kv = jnp.einsum("bhn,bhm->bhnm", kk, vv)
    y = jnp.einsum("bhn,bhnm->bhm", rr, S + u[None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    y = y.reshape(B, 1, d) * g.astype(jnp.float32)
    return (y @ p["w_o"]).astype(x.dtype), {"S": S_new, "x_last": x[:, -1, :]}


def rwkv_init_state(cfg, batch, dtype):
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    return {"S": jnp.zeros((batch, H, N, N), jnp.float32),
            "x_last": jnp.zeros((batch, d), dtype)}
