"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Scalable dispatch (no (T, E, C) one-hot tensors): tokens are scattered
into per-expert capacity buffers via cumulative-sum position assignment,
expert FFNs run as a single batched einsum over (E, C, d), and results
are gathered back with router-probability weighting.  Expert weights are
tensor-parallel over the 'model' mesh axis (d_ff dim); token buffers stay
on the data shards, so no all_to_all is needed in the baseline schedule
(see DESIGN.md §5 — the all_to_all expert-parallel layout is the
hillclimb alternative).

Router load-balance auxiliary loss per Shazeer et al. / Mixtral.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def moe_init(key, cfg, dtype):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "w_gate": layers.dense_init(ks[1], (E, d, f), dtype),
        "w_up": layers.dense_init(ks[2], (E, d, f), dtype),
        "w_down": layers.dense_init(ks[3], (E, f, d), dtype),
    }


def _expert_ffn_chunked(p, buf, chunk=2048):
    """buf: (E, C, d) -> (E, C, d); capacity-chunked SwiGLU experts."""
    E, C, d = buf.shape
    c = min(chunk, C)
    if C % c:
        c = C                           # small/odd capacities: one shot

    def ffn(b):
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", b, p["w_gate"]))
        up = jnp.einsum("ecd,edf->ecf", b, p["w_up"])
        return jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])

    if c == C:
        return ffn(buf)
    chunks = buf.reshape(E, C // c, c, d).swapaxes(0, 1)   # (n, E, c, d)
    outs = jax.lax.map(ffn, chunks)
    return outs.swapaxes(0, 1).reshape(E, C, d)


def moe_apply(p, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- load-balance aux loss (fraction-of-tokens * mean-prob per expert)
    me = jnp.mean(probs, axis=0)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (T, k, E)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- capacity-based dispatch
    capacity = int(cfg.capacity_factor * k * T / E)
    capacity = max(8, -(-capacity // 8) * 8)
    flat_idx = expert_idx.reshape(T * k)                     # slot-major? token-major
    flat_gate = gate_vals.reshape(T * k)
    # position of each (token, slot) within its expert's buffer
    eh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)        # (T*k, E)
    pos_in_expert = (jnp.cumsum(eh, axis=0) - eh)            # (T*k, E)
    pos = jnp.sum(pos_in_expert * eh, axis=-1)               # (T*k,)
    keep = pos < capacity
    dest = flat_idx * capacity + jnp.where(keep, pos, capacity)  # overflow slot

    buf = jnp.zeros((E * capacity + 1, d), x.dtype)
    token_ids = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[jnp.where(keep, dest, E * capacity)].set(
        xf[token_ids], mode="drop")
    buf = buf[:E * capacity].reshape(E, capacity, d)

    # --- expert FFNs (batched over experts; d_ff sharded over 'model');
    # chunk the capacity dim so the (E, C, d_ff) intermediates never
    # materialize whole (C can reach ~20k at prefill_32k)
    out = _expert_ffn_chunked(p, buf)

    # --- combine
    out_flat = out.reshape(E * capacity, d)
    gathered = out_flat[jnp.minimum(dest, E * capacity - 1)]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered * flat_gate[:, None].astype(gathered.dtype)
    y = jnp.sum(weighted.reshape(T, k, d), axis=1)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# expert-parallel variant: move tokens, not expert weights
# ---------------------------------------------------------------------------
def moe_apply_ep(p, x, cfg, *, axis_name, ep_degree=None):
    """Expert-parallel MoE for use inside a ``jax.shard_map`` manual
    region over ``axis_name`` (the hillclimb alternative to the TP/FSDP
    layouts — expert weights stay resident on their shard group and the
    capacity buffers travel through one all_to_all each way).

    Preconditions: every shard holds the full (E, d, f) expert weights
    sliced so that shard ``i`` *uses* experts
    ``[i*E/W .. (i+1)*E/W)`` (W = ep_degree = axis size; E % W == 0).
    Tokens are locally routed, packed into per-expert capacity buffers,
    exchanged with all_to_all so each shard computes only its experts,
    and returned.  Numerics match :func:`moe_apply` up to capacity-drop
    ordering (validated in tests/test_moe_ep.py).
    """
    from repro.compat import axis_size as _axis_size
    W = _axis_size(axis_name)
    E, k = cfg.n_experts, cfg.experts_per_token
    assert E % W == 0, (E, W)
    E_loc = E // W
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    capacity = int(cfg.capacity_factor * k * T / E)
    capacity = max(8, -(-capacity // 8) * 8)
    flat_idx = expert_idx.reshape(T * k)
    flat_gate = gate_vals.reshape(T * k)
    eh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(eh, axis=0) - eh) * eh, axis=-1)
    keep = pos < capacity
    dest = flat_idx * capacity + jnp.where(keep, pos, capacity)

    buf = jnp.zeros((E * capacity + 1, d), x.dtype)
    token_ids = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[jnp.where(keep, dest, E * capacity)].set(
        xf[token_ids], mode="drop")
    buf = buf[:E * capacity].reshape(E, capacity, d)

    # ship each expert's buffer to the shard that owns it; receive the
    # buffers of OUR experts from every peer: (E, C, d) -> (W*E_loc, C, d)
    shipped = jax.lax.all_to_all(
        buf.reshape(W, E_loc, capacity, d), axis_name,
        split_axis=0, concat_axis=0, tiled=True)      # (W, E_loc, C, d)

    # compute only the local experts (weights sliced to our group)
    shard = jax.lax.axis_index(axis_name)
    wg = jax.lax.dynamic_slice_in_dim(p["w_gate"], shard * E_loc, E_loc, 0)
    wu = jax.lax.dynamic_slice_in_dim(p["w_up"], shard * E_loc, E_loc, 0)
    wd = jax.lax.dynamic_slice_in_dim(p["w_down"], shard * E_loc, E_loc, 0)
    flat_in = shipped.transpose(1, 0, 2, 3).reshape(E_loc, W * capacity, d)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", flat_in, wg))
    up = jnp.einsum("ecd,edf->ecf", flat_in, wu)
    res = jnp.einsum("ecf,efd->ecd", gate * up, wd)

    # return results to the owners of the tokens
    back = res.reshape(E_loc, W, capacity, d).transpose(1, 0, 2, 3)
    out = jax.lax.all_to_all(back, axis_name, split_axis=0,
                             concat_axis=0, tiled=True)  # (W, E_loc, C, d)
    out_flat = out.reshape(E * capacity, d)

    gathered = out_flat[jnp.minimum(dest, E * capacity - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered * flat_gate[:, None].astype(gathered.dtype)
    y = jnp.sum(weighted.reshape(T, k, d), axis=1)
    return y.reshape(B, S, d).astype(x.dtype), aux
