"""Attention: GQA projections, chunked (flash-style) softmax attention with
causal / sliding-window masking, and single-token KV-cache decode.

The chunked implementation is the default lowering path (pure ``jnp`` +
``lax.scan`` with online softmax => O(seq) live memory).  Out-of-window /
fully-masked KV chunks are skipped with ``lax.cond`` so sliding-window
attention does O(S*W) work, not O(S^2).  The Pallas kernel in
``repro.kernels.swa_attention`` is the drop-in optimized path
(``use_pallas=True`` in :func:`repro.models.transformer.build_model`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def attention_init(key, cfg, dtype, cross=False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, H * hd), dtype),
        "wk": layers.dense_init(ks[1], (d, KV * hd), dtype),
        "wv": layers.dense_init(ks[2], (d, KV * hd), dtype),
        "wo": layers.dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def project_qkv(p, x, cfg):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KV, hd),
            v.reshape(B, S, KV, hd))


# ---------------------------------------------------------------------------
# flash attention (train / prefill): chunked fwd + chunked two-pass bwd
# wrapped in a custom VJP so the backward never materializes O(S^2)
# residuals (the fix that makes 4k-train / 32k-prefill fit in HBM).
# ---------------------------------------------------------------------------
def _block_mask(q_pos, kv_pos, Sq, Skv, causal, window):
    mask = (kv_pos[None, :] <= Skv - 1) & (q_pos[:, None] <= Sq - 1)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
    return mask


def _relevant(q_lo, q_hi, k_lo, k_hi, causal, window):
    """Static/traced predicate: does kv block [k_lo,k_hi) intersect the
    attention span of q block [q_lo,q_hi)?"""
    rel = jnp.asarray(True)
    if causal:
        rel = rel & (k_lo <= q_hi - 1)
    if window is not None:
        rel = rel & (k_hi > q_lo - window + 1)
    return rel


def _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk):
    """Returns (out (B,Sq,H,hd), lse (B,Sq,G,KV))."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Skv), (0, 0), (0, 0)))
    qp = qp.reshape(B, nq, q_chunk, KV, G, hd)
    kp = kp.reshape(B, nk, kv_chunk, KV, hd)
    vp = vp.reshape(B, nk, kv_chunk, KV, hd)
    scale = 1.0 / (hd ** 0.5)

    def q_block(args):
        qi, qblk = args
        q_lo = qi * q_chunk
        q_pos = q_lo + jnp.arange(q_chunk)

        def kv_step(carry, kin):
            m, l, acc = carry
            ki, kblk, vblk = kin
            k_lo = ki * kv_chunk
            kv_pos = k_lo + jnp.arange(kv_chunk)

            def attend(_):
                s = jnp.einsum("bqkgh,bskh->bqgks", qblk, kblk,
                               preferred_element_type=jnp.float32) * scale
                mask = _block_mask(q_pos, kv_pos, Sq, Skv, causal, window)
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bqgks,bskh->bqgkh", p.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc_new

            rel = _relevant(q_lo, q_lo + q_chunk, k_lo, k_lo + kv_chunk,
                            causal, window)
            new = jax.lax.cond(rel, attend, lambda _: (m, l, acc),
                               operand=None)
            return new, None

        m0 = jnp.full((B, q_chunk, G, KV), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, G, KV), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, G, KV, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kp.swapaxes(0, 1),
                                    vp.swapaxes(0, 1)))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B,qc,G,KV)
        return out, lse

    outs, lses = jax.lax.map(q_block, (jnp.arange(nq), qp.swapaxes(0, 1)))
    outs = outs.transpose(1, 0, 2, 4, 3, 5).reshape(B, nq * q_chunk, H, hd)
    lses = lses.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, G, KV)
    return outs[:, :Sq], lses[:, :Sq]


def _flash_bwd_impl(q, k, v, out, lse, do, causal, window, q_chunk,
                    kv_chunk):
    """Two-pass chunked backward (dq pass; dk/dv pass)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    scale = 1.0 / (hd ** 0.5)

    pad4 = lambda x, n: jnp.pad(x, ((0, 0), (0, n), (0, 0), (0, 0)))
    qp = pad4(q, nq * q_chunk - Sq).reshape(B, nq, q_chunk, KV, G, hd)
    dop = pad4(do, nq * q_chunk - Sq).reshape(B, nq, q_chunk, KV, G, hd)
    op = pad4(out, nq * q_chunk - Sq).reshape(B, nq, q_chunk, KV, G, hd)
    lsep = jnp.pad(lse, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)),
                   constant_values=0.0) \
        .reshape(B, nq, q_chunk, G, KV)
    kp = pad4(k, nk * kv_chunk - Skv).reshape(B, nk, kv_chunk, KV, hd)
    vp = pad4(v, nk * kv_chunk - Skv).reshape(B, nk, kv_chunk, KV, hd)

    # D = rowsum(do * out)  per (b, q, g, kv)
    Dp = jnp.einsum("bnqkgh,bnqkgh->bnqgk", dop.astype(jnp.float32),
                    op.astype(jnp.float32))

    def p_block(qblk, lseblk, kblk, vblk, q_lo, k_lo):
        q_pos = q_lo + jnp.arange(q_chunk)
        kv_pos = k_lo + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgh,bskh->bqgks", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask(q_pos, kv_pos, Sq, Skv, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        return jnp.exp(s - lseblk.transpose(0, 1, 2, 3)[..., None])

    # ---- pass 1: dq per q block ----
    def dq_block(args):
        qi, qblk, doblk, lseblk, Dblk = args
        q_lo = qi * q_chunk

        def kv_step(dq, kin):
            ki, kblk, vblk = kin
            k_lo = ki * kv_chunk

            def go(dq):
                p = p_block(qblk, lseblk, kblk, vblk, q_lo, k_lo)
                dp = jnp.einsum("bqkgh,bskh->bqgks",
                                doblk.astype(jnp.float32),
                                vblk.astype(jnp.float32))
                ds = p * (dp - Dblk[..., None])
                return dq + jnp.einsum("bqgks,bskh->bqkgh", ds,
                                       kblk.astype(jnp.float32)) * scale
            rel = _relevant(q_lo, q_lo + q_chunk, k_lo, k_lo + kv_chunk,
                            causal, window)
            return jax.lax.cond(rel, go, lambda d: d, dq), None

        dq0 = jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32)
        dq, _ = jax.lax.scan(kv_step, dq0,
                             (jnp.arange(nk), kp.swapaxes(0, 1),
                              vp.swapaxes(0, 1)))
        return dq

    dqs = jax.lax.map(dq_block, (jnp.arange(nq), qp.swapaxes(0, 1),
                                 dop.swapaxes(0, 1), lsep.swapaxes(0, 1),
                                 Dp.swapaxes(0, 1)))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, hd)

    # ---- pass 2: dk/dv per kv block ----
    def dkv_block(args):
        ki, kblk, vblk = args
        k_lo = ki * kv_chunk

        def q_step(carry, qin):
            dk, dv = carry
            qi, qblk, doblk, lseblk, Dblk = qin
            q_lo = qi * q_chunk

            def go(carry):
                dk, dv = carry
                p = p_block(qblk, lseblk, kblk, vblk, q_lo, k_lo)
                dv = dv + jnp.einsum("bqgks,bqkgh->bskh", p,
                                     doblk.astype(jnp.float32))
                dp = jnp.einsum("bqkgh,bskh->bqgks",
                                doblk.astype(jnp.float32),
                                vblk.astype(jnp.float32))
                ds = p * (dp - Dblk[..., None])
                dk = dk + jnp.einsum("bqgks,bqkgh->bskh", ds,
                                     qblk.astype(jnp.float32)) * scale
                return dk, dv
            rel = _relevant(q_lo, q_lo + q_chunk, k_lo, k_lo + kv_chunk,
                            causal, window)
            return jax.lax.cond(rel, go, lambda c: c, (dk, dv)), None

        z = jnp.zeros((B, kv_chunk, KV, hd), jnp.float32)
        (dk, dv), _ = jax.lax.scan(
            q_step, (z, z),
            (jnp.arange(nq), qp.swapaxes(0, 1), dop.swapaxes(0, 1),
             lsep.swapaxes(0, 1), Dp.swapaxes(0, 1)))
        return dk, dv

    dks, dvs = jax.lax.map(dkv_block, (jnp.arange(nk), kp.swapaxes(0, 1),
                                       vp.swapaxes(0, 1)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_chunk, KV, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_chunk, KV, hd)
    return (dq[:, :Sq].astype(q.dtype), dk[:, :Skv].astype(k.dtype),
            dv[:, :Skv].astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, q_chunk, kv_chunk):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out


def _flash_fwd_rule(q, k, v, causal, window, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, do, causal, window, q_chunk,
                           kv_chunk)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def chunked_attention(q, k, v, *, causal=True, window=None,
                      q_chunk=512, kv_chunk=512, pallas_fn=None):
    """Flash attention (see module docstring).

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0.
    ``window``: query at position i attends to [i-window+1, i].
    """
    if pallas_fn is not None and causal and q.shape[1] == k.shape[1]:
        return pallas_fn(q, k, v, window=window)
    return _flash(q, k, v, causal, window, q_chunk, kv_chunk)


# ---------------------------------------------------------------------------
# decode attention (single new token vs KV cache)
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, pos, *, window=None):
    """q: (B, 1, H, hd); caches: (B, L, KV, hd) ring buffers.

    ``pos`` is the position (int32 scalar or (B,)) of the new token.  Slot
    ``s`` of a ring buffer of length L holds sequence position
    ``pos - ((pos - s) mod L)``; slots with negative positions are invalid.
    """
    B, L, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (B,))

    slots = jnp.arange(L)
    slot_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - slots[None, :], L)
    valid = slot_pos >= 0
    if window is not None:
        valid = valid & (slot_pos > pos_b[:, None] - window)

    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,blkh->bgkl", qg, k_cache,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgkl,blkh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def decode_attention_quant(q, k_cache, v_cache, pos, *, window=None):
    """decode_attention against int8-quantized caches
    ({"q": int8, "scale": fp16} per k/v — repro.models.kvquant).
    Dequantization folds into the fp32 score/value einsums (scales are
    rank-1 per cache entry), so no full-precision cache materializes.
    """
    kq, ks = k_cache["q"], k_cache["scale"]
    vq, vs = v_cache["q"], v_cache["scale"]
    B, L, KV, hd = kq.shape
    H = q.shape[2]
    G = H // KV
    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (B,))
    slots = jnp.arange(L)
    slot_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - slots[None, :], L)
    valid = slot_pos >= 0
    if window is not None:
        valid = valid & (slot_pos > pos_b[:, None] - window)

    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,blkh->bgkl", qg, kq.astype(jnp.float32),
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = s * ks[..., 0].transpose(0, 2, 1)[:, None]       # (B,1,KV,L)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * vs[..., 0].transpose(0, 2, 1)[:, None]      # fold v scales
    out = jnp.einsum("bgkl,blkh->bkgh", pv, vq.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Write (B,1,KV,hd) new entries at ring slot pos % L.

    ``pos`` may be a scalar (all requests aligned) or (B,) per-slot
    positions (continuous batching — repro.serving.engine)."""
    L = k_cache.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        slot = jnp.mod(pos, L)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot,
                                                      axis=1)
        return k_cache, v_cache
    B = k_cache.shape[0]
    rows = jnp.arange(B)
    slots = jnp.mod(pos, L)
    return (k_cache.at[rows, slots].set(k_new[:, 0]),
            v_cache.at[rows, slots].set(v_new[:, 0]))
