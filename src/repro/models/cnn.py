"""The paper's CNN models in pure JAX: MobileNet-style (depthwise-
separable) and ResNet-18, adapted to 32x32 CIFAR inputs.

GroupNorm replaces BatchNorm (functional purity — no running stats to
thread through the five sync strategies; convergence comparisons between
strategies are unaffected, noted in DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _conv_init(key, shape, dtype=jnp.float32):
    fan_in = np.prod(shape[:-1])
    return jax.random.normal(key, shape, dtype) * np.sqrt(2.0 / fan_in)


def conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def groupnorm(x, scale, bias, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(B, H, W, C) * scale + bias


def _gn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


# ---------------------------------------------------------------------------
# MobileNet (v1-style, CIFAR stride schedule) — ~4.2M params at width 1.0
# ---------------------------------------------------------------------------
_MOBILENET_CFG = [  # (out_channels, stride)
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
    (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
]


def mobilenet_init(key, cfg):
    wm = cfg.width_mult
    ch = lambda c: max(8, int(c * wm))
    ks = jax.random.split(key, 2 + 2 * len(_MOBILENET_CFG))
    params = {"stem": {"w": _conv_init(ks[0], (3, 3, cfg.channels, ch(32))),
                       "gn": _gn_init(ch(32))}}
    blocks = []
    c_in = ch(32)
    for i, (c_out, stride) in enumerate(_MOBILENET_CFG):
        c_out = ch(c_out)
        blocks.append({
            "dw": {"w": _conv_init(ks[1 + 2 * i], (3, 3, 1, c_in)),
                   "gn": _gn_init(c_in)},
            "pw": {"w": _conv_init(ks[2 + 2 * i], (1, 1, c_in, c_out)),
                   "gn": _gn_init(c_out)},
        })
        c_in = c_out
    params["blocks"] = blocks
    params["head"] = {
        "w": jax.random.normal(ks[-1], (c_in, cfg.num_classes)) *
        (1.0 / np.sqrt(c_in)),
        "b": jnp.zeros((cfg.num_classes,))}
    return params


def mobilenet_apply(params, images):
    x = conv(images, params["stem"]["w"], stride=1)
    x = jax.nn.relu(groupnorm(x, **params["stem"]["gn"]))
    for blk, (_, s) in zip(params["blocks"], _MOBILENET_CFG):
        x = conv(x, blk["dw"]["w"], stride=s, groups=x.shape[-1])
        x = jax.nn.relu(groupnorm(x, blk["dw"]["gn"]["scale"],
                                  blk["dw"]["gn"]["bias"]))
        x = conv(x, blk["pw"]["w"])
        x = jax.nn.relu(groupnorm(x, blk["pw"]["gn"]["scale"],
                                  blk["pw"]["gn"]["bias"]))
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# ResNet-18 (CIFAR variant: 3x3 stem, no maxpool) — 11.7M params
# ---------------------------------------------------------------------------
_RESNET_STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]  # 2 blocks each


def resnet18_init(key, cfg):
    wm = cfg.width_mult
    ch = lambda c: max(8, int(c * wm))
    keys = iter(jax.random.split(key, 64))
    params = {"stem": {"w": _conv_init(next(keys), (3, 3, cfg.channels,
                                                    ch(64))),
                       "gn": _gn_init(ch(64))}}
    stages = []
    c_in = ch(64)
    for c_out, stride in _RESNET_STAGES:
        c_out = ch(c_out)
        blocks = []
        for b in range(2):
            s = stride if b == 0 else 1
            blk = {
                "c1": {"w": _conv_init(next(keys), (3, 3, c_in, c_out)),
                       "gn": _gn_init(c_out)},
                "c2": {"w": _conv_init(next(keys), (3, 3, c_out, c_out)),
                       "gn": _gn_init(c_out)},
            }
            if s != 1 or c_in != c_out:
                blk["proj"] = {"w": _conv_init(next(keys),
                                               (1, 1, c_in, c_out)),
                               "gn": _gn_init(c_out)}
            blocks.append(blk)
            c_in = c_out
        stages.append(blocks)
    params["stages"] = stages
    params["head"] = {
        "w": jax.random.normal(next(keys), (c_in, cfg.num_classes)) *
        (1.0 / np.sqrt(c_in)),
        "b": jnp.zeros((cfg.num_classes,))}
    return params


def resnet18_apply(params, images):
    x = conv(images, params["stem"]["w"])
    x = jax.nn.relu(groupnorm(x, **params["stem"]["gn"]))
    for stage, (_, stride) in zip(params["stages"], _RESNET_STAGES):
        for b, blk in enumerate(stage):
            s = stride if b == 0 else 1
            h = conv(x, blk["c1"]["w"], stride=s)
            h = jax.nn.relu(groupnorm(h, blk["c1"]["gn"]["scale"],
                                      blk["c1"]["gn"]["bias"]))
            h = conv(h, blk["c2"]["w"])
            h = groupnorm(h, blk["c2"]["gn"]["scale"], blk["c2"]["gn"]["bias"])
            if "proj" in blk:
                x = conv(x, blk["proj"]["w"], stride=s)
                x = groupnorm(x, blk["proj"]["gn"]["scale"],
                              blk["proj"]["gn"]["bias"])
            x = jax.nn.relu(x + h)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


class CNNModel:
    """Uniform interface used by the training/serverless layers."""

    def __init__(self, cfg):
        self.cfg = cfg
        if cfg.kind == "mobilenet":
            self._init, self._apply = mobilenet_init, mobilenet_apply
        elif cfg.kind == "resnet18":
            self._init, self._apply = resnet18_init, resnet18_apply
        else:
            raise ValueError(cfg.kind)

    def init(self, key):
        return self._init(key, self.cfg)

    def apply(self, params, batch):
        return self._apply(params, batch["images"]), jnp.zeros((),
                                                               jnp.float32)


def build_cnn(cfg) -> CNNModel:
    return CNNModel(cfg)
