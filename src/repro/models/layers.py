"""Core neural-net building blocks (functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def shard_hint(x, *axes):
    """with_sharding_constraint on auto mesh axes, if any are in scope.

    ``axes`` entries are mesh-axis names (or None) per tensor dim; axes
    not present in the current abstract mesh are dropped, so model code
    stays mesh-agnostic (no-op on CPU tests / 1x1 meshes)."""
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) or ()
    try:  # only Auto axes may appear in with_sharding_constraint specs
        types = dict(zip(names, mesh.axis_types))
        names = tuple(n for n in names
                      if types[n] == jax.sharding.AxisType.Auto)
    except AttributeError:
        pass
    spec = tuple(a if (a in names) else None for a in axes)
    if not any(spec):
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w + b).astype(x.dtype)


def rmsnorm_init(d):
    return jnp.zeros((d,), jnp.float32)


def layernorm_init(d):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim, theta):
    # head_dim may be odd-unfriendly; use the even prefix
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_frequencies(hd, theta)                      # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:2 * half].astype(jnp.float32)
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rot1, rot2, x[..., 2 * half:].astype(jnp.float32)],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len, d_model):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d_model)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def sinusoidal_position_at(pos, d_model):
    """Sinusoidal embedding for a traced position scalar or (B,) array."""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    p = jnp.asarray(pos, jnp.float32)
    angle = p[..., None] / jnp.power(10_000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def mlp_apply(p, x, kind):
    if kind == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"])
        return (gate * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def embedding_init(key, vocab, d_model, dtype):
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    # separate unembedding head (vocab-parallel when sharded)
    return x @ p["table"]


def unembed_init(key, d_model, vocab, dtype):
    return {"table": dense_init(key, (d_model, vocab), dtype)}
