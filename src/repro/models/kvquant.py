"""int8 KV-cache quantization (beyond paper — the §Roofline lever for
memory-dominant decode shapes).

Per-entry symmetric quantization with fp16-scale-per-(position, head):
cache bytes drop ~2x vs bf16 (int8 payload + 2-byte scale per hd-vector),
and decode reads correspondingly less HBM.  Dequantization happens in
the attention einsum's fp32 accumulator, so accuracy loss is bounded by
|x|/127 per element (validated in tests/test_kvquant.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_kv(x):
    """x: (..., hd) -> (int8 payload, fp16 per-vector scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def init_quant_cache(batch, length, kv_heads, head_dim, stacked=()):
    shape = tuple(stacked) + (batch, length, kv_heads, head_dim)
    return {"q": jnp.zeros(shape, jnp.int8),
            "scale": jnp.zeros(shape[:-1] + (1,), jnp.float16)}


def quant_cache_update(cache, new, pos):
    """cache: {"q","scale"}; new: (B, 1, KV, hd) raw values."""
    L = cache["q"].shape[-3]
    slot = jnp.mod(pos, L)
    qn, sn = quantize_kv(new)
    return {
        "q": jax.lax.dynamic_update_slice_in_dim(cache["q"], qn, slot,
                                                 axis=-3),
        "scale": jax.lax.dynamic_update_slice_in_dim(cache["scale"], sn,
                                                     slot, axis=-3),
    }
