from repro.models.transformer import Model, build_model  # noqa: F401
from repro.models.cnn import CNNModel, build_cnn  # noqa: F401
