"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Diagonal recurrence => parallelized with ``jax.lax.associative_scan``
over time (the TPU-native form; a GPU implementation would use a fused
linear-scan kernel).  The block is Griffin's recurrent block: linear in,
short temporal conv, RG-LRU, gated linear out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0  # Griffin's recurrence sharpness constant


def rglru_init(key, cfg, dtype):
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(key, 6)
    return {
        "w_in": layers.dense_init(ks[0], (d, w), dtype),
        "w_gate_in": layers.dense_init(ks[1], (d, w), dtype),
        "w_a": layers.dense_init(ks[2], (w, w), dtype, scale=0.01),
        "w_i": layers.dense_init(ks[3], (w, w), dtype, scale=0.01),
        "lam": jnp.full((w,), 2.0, jnp.float32),   # softplus(2) ≈ 2.1
        "conv_w": (jax.random.normal(ks[4], (cfg.conv_width, w), jnp.float32)
                   * 0.1).astype(dtype),
        "w_out": layers.dense_init(ks[5], (w, d), dtype),
    }


def _gates(p, u):
    """u: (B, T, w) post-conv activations -> (a, gated_input) in fp32."""
    uf = u.astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"]) * jax.nn.sigmoid(
        uf @ p["w_a"].astype(jnp.float32))
    a = jnp.exp(log_a)
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    x_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, x_in


def _conv(p, u, conv_state):
    """Causal depthwise temporal conv, width cfg.conv_width.

    u: (B, T, w); conv_state: (B, cw-1, w) trailing inputs of the previous
    segment.  Returns (out, new_conv_state).
    """
    cw = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    out = sum(full[:, i:i + u.shape[1], :] * p["conv_w"][i]
              for i in range(cw))
    return out, full[:, -(cw - 1):, :]


def rglru_apply(p, x, cfg, state=None):
    """Full-sequence form. x: (B, T, d) -> (y, new_state)."""
    B, T, d = x.shape
    w = cfg.rglru_width
    if state is None:
        state = rglru_init_state(cfg, B, x.dtype)
    u = x @ p["w_in"]
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    u, conv_state = _conv(p, u, state["conv"])
    a, x_in = _gates(p, u)

    # associative scan over time: (a, b) pairs compose as
    # (a2*a1, a2*b1 + b2); seed position 0 with the carried h.
    x_in = x_in.at[:, 0, :].add(a[:, 0, :] * state["h"])

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, {"h": h[:, -1, :], "conv": conv_state}


def rglru_decode_step(p, x, cfg, state):
    """Single-token recurrence. x: (B, 1, d)."""
    u = x @ p["w_in"]
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    u, conv_state = _conv(p, u, state["conv"])
    a, x_in = _gates(p, u)
    h = a[:, 0] * state["h"] + x_in[:, 0]
    y = (h[:, None, :].astype(x.dtype) * gate) @ p["w_out"]
    return y, {"h": h, "conv": conv_state}


def rglru_init_state(cfg, batch, dtype):
    w = cfg.rglru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}
