"""Config-driven model assembly for all assigned architectures.

One ``Model`` object per config, exposing:

  init(key)                          -> params pytree
  apply(params, batch)               -> (logits, aux)        [train fwd]
  prefill(params, batch, cache_len)  -> (logits_last, cache)
  decode_step(params, token, cache, pos) -> (logits, cache)

Depth is organized as ``lax.scan`` over repeating layer-pattern blocks
(homogeneous stacks => small HLO, fast multi-arch dry-runs), with the
remainder layers unrolled ("tail").  Layer kinds: global attention,
local (sliding-window) attention, RG-LRU recurrence, RWKV6 time-mix.
MoE configs replace every MLP with the top-k expert layer.

Modality frontends (audio conv codec, ViT patch encoder) are stubs per
the assignment: batches carry precomputed ``frames`` / ``patch_emb``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL, LOCAL, RGLRU, RWKV, ModelConfig
from repro.models import attention, layers, moe, rglru, rwkv6


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------
def _layer_init(key, kind: str, cfg: ModelConfig, dtype, cross=False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.rmsnorm_init(cfg.d_model),
                         "norm2": layers.rmsnorm_init(cfg.d_model)}
    if kind in (GLOBAL, LOCAL):
        p["attn"] = attention.attention_init(k1, cfg, dtype)
    elif kind == RGLRU:
        p["rglru"] = rglru.rglru_init(k1, cfg, dtype)
    elif kind == RWKV:
        p["rwkv"] = rwkv6.rwkv_init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = layers.rmsnorm_init(cfg.d_model)
        p["xattn"] = attention.attention_init(k3, cfg, dtype)
    if cfg.is_moe and kind in (GLOBAL, LOCAL):
        p["moe"] = moe.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def _mixer_train(p, kind, x, positions, cfg, use_rope, pallas_fn):
    """Sequence-mixer forward over a full sequence (train/prefill)."""
    if kind in (GLOBAL, LOCAL):
        q, k, v = attention.project_qkv(p["attn"], x, cfg)
        if use_rope:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
        window = cfg.window if kind == LOCAL else None
        o = attention.chunked_attention(q, k, v, causal=True, window=window,
                                        pallas_fn=pallas_fn)
        B, S, _, _ = o.shape
        y = o.reshape(B, S, -1) @ p["attn"]["wo"]
        return y, (k, v)
    if kind == RGLRU:
        y, _ = rglru.rglru_apply(p["rglru"], x, cfg)
        return y, None
    if kind == RWKV:
        y, _ = rwkv6.rwkv_apply(p["rwkv"], x, cfg,
                                use_kernel=pallas_fn is not None)
        return y, None
    raise ValueError(kind)


def _layer_train(p, kind, x, positions, cfg, use_rope, pallas_fn,
                 enc_out=None):
    """Full transformer layer (pre-norm): mixer -> [cross-attn] -> FFN."""
    h = layers.rmsnorm(x, p["norm1"])
    mix, _ = _mixer_train(p, kind, h, positions, cfg, use_rope, pallas_fn)
    x = x + mix
    if enc_out is not None:
        h = layers.rmsnorm(x, p["norm_x"])
        q, _, _ = attention.project_qkv(p["xattn"], h, cfg)
        _, k, v = attention.project_qkv(p["xattn"], enc_out, cfg)
        o = attention.chunked_attention(q, k, v, causal=False)
        x = x + o.reshape(*o.shape[:2], -1) @ p["xattn"]["wo"]
    h = layers.rmsnorm(x, p["norm2"])
    if "moe" in p:
        y, aux = moe.moe_apply(p["moe"], h, cfg)
    else:
        y, aux = layers.mlp_apply(p["mlp"], h, cfg.mlp), 0.0
    return x + y, aux


# ---------------------------------------------------------------------------
# pattern-block organization
# ---------------------------------------------------------------------------
def _split_depth(cfg: ModelConfig):
    P = len(cfg.layer_pattern)
    n_blocks = cfg.n_layers // P
    n_tail = cfg.n_layers - n_blocks * P
    tail_kinds = cfg.layer_pattern[:n_tail]
    return n_blocks, tail_kinds


class Model:
    def __init__(self, cfg: ModelConfig, use_pallas: bool = False,
                 remat: bool = True, remat_policy=None,
                 kv_quant: bool = False):
        self.cfg = cfg
        self.remat = remat
        # int8 KV caches (repro.models.kvquant) — beyond-paper lever for
        # memory-dominant decode shapes (EXPERIMENTS.md §Perf)
        self.kv_quant = kv_quant
        # e.g. jax.checkpoint_policies.save_only_these_names("fsdp_gather")
        # keeps FSDP param gathers out of the backward re-gather
        self.remat_policy = remat_policy
        self.use_rope = not cfg.is_encoder_decoder
        self.pallas_fn = None
        if use_pallas:
            from repro.kernels import ops as kops
            self.pallas_fn = kops.swa_attention
        self.n_blocks, self.tail_kinds = _split_depth(cfg)
        # FSDP hook: fn(param_subtree, kind in {"block","tail"}, idx) ->
        # gathered subtree.  Set by the train-step builder (manual-mesh
        # regions only); identity when None.
        self.param_hook = None

    def _hook(self, tree, kind, idx):
        if self.param_hook is None:
            return tree
        return self.param_hook(tree, kind, idx)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 128 multiple so the embedding/unembedding can
        always shard over the model axis (standard vocab padding; the
        extra logits correspond to never-labeled classes)."""
        return -(-self.cfg.vocab_size // 128) * 128

    # --------------------------- init ---------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": layers.embedding_init(keys[0], self.padded_vocab,
                                           cfg.d_model, dtype),
            "unembed": layers.unembed_init(keys[1], cfg.d_model,
                                           self.padded_vocab, dtype),
            "final_norm": layers.rmsnorm_init(cfg.d_model),
        }
        cross = cfg.is_encoder_decoder
        # stacked block params: one stacked tree per pattern position
        block_keys = jax.random.split(keys[2], max(self.n_blocks, 1))
        blocks = []
        for j, kind in enumerate(cfg.layer_pattern):
            def one(k):
                return _layer_init(jax.random.fold_in(k, j), kind, cfg,
                                   dtype, cross=cross)
            if self.n_blocks > 0:
                blocks.append(jax.vmap(one)(block_keys))
        params["blocks"] = blocks
        params["tail"] = [
            _layer_init(jax.random.fold_in(keys[3], i), kind, cfg, dtype,
                        cross=cross)
            for i, kind in enumerate(self.tail_kinds)]
        if cfg.is_encoder_decoder:
            enc_keys = jax.random.split(keys[4], cfg.n_encoder_layers)
            def enc_one(k):
                return _layer_init(k, GLOBAL, cfg, dtype, cross=False)
            params["encoder"] = jax.vmap(enc_one)(enc_keys)
            params["enc_norm"] = layers.rmsnorm_init(cfg.d_model)
        return params

    # --------------------------- embedding ----------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm" and "patch_emb" in batch:
            npatch = batch["patch_emb"].shape[1]
            x = jnp.concatenate(
                [batch["patch_emb"].astype(x.dtype), x[:, npatch:]], axis=1)
        if cfg.is_encoder_decoder:
            S = x.shape[1]
            x = x + layers.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
        return x

    def _encode(self, params, frames):
        """Whisper-style encoder over stub frame embeddings."""
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + layers.sinusoidal_positions(x.shape[1],
                                            cfg.d_model).astype(x.dtype)
        positions = jnp.arange(x.shape[1])

        def body(x, p):
            h = layers.rmsnorm(x, p["norm1"])
            q, k, v = attention.project_qkv(p["attn"], h, cfg)
            o = attention.chunked_attention(q, k, v, causal=False)
            x = x + o.reshape(*o.shape[:2], -1) @ p["attn"]["wo"]
            h = layers.rmsnorm(x, p["norm2"])
            x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp)
            return x, None

        fn = jax.checkpoint(body, policy=self.remat_policy) \
            if self.remat else body
        x, _ = jax.lax.scan(lambda c, p: fn(c, p), x, params["encoder"])
        return layers.rmsnorm(x, params["enc_norm"])

    # --------------------------- train forward ------------------------
    def apply(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :]
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self._encode(params, batch["frames"])

        def layer(x, p, kind):
            return _layer_train(p, kind, x, positions, cfg, self.use_rope,
                                self.pallas_fn, enc_out=enc_out)

        def block_body(carry, block_params):
            x, aux = carry
            for j, kind in enumerate(cfg.layer_pattern):
                x, a = layer(x, self._hook(block_params[j], "block", j), kind)
                aux = aux + a
            return (x, aux), None

        fn = jax.checkpoint(block_body, policy=self.remat_policy) \
            if self.remat else block_body
        carry = (x, jnp.zeros((), jnp.float32))
        if self.n_blocks > 0:
            carry, _ = jax.lax.scan(fn, carry, tuple(params["blocks"]))
        x, aux = carry
        for i, (p, kind) in enumerate(zip(params["tail"], self.tail_kinds)):
            x, a = layer(x, self._hook(p, "tail", i), kind)
            aux = aux + a
        x = layers.rmsnorm(x, params["final_norm"])
        logits = layers.unembed(params["unembed"], x)
        return logits, aux

    # --------------------------- cache --------------------------------
    def _cache_len(self, kind: str, seq_len: int) -> int:
        if kind == GLOBAL:
            return seq_len
        return min(self.cfg.window, seq_len)

    def init_cache(self, batch_size: int, seq_len: int,
                   swa_variant: bool = False) -> Dict[str, Any]:
        """Empty decode cache for a maximum context of ``seq_len``."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        pattern = self._pattern(swa_variant)

        def one(kind):
            if kind in (GLOBAL, LOCAL):
                L = self._cache_len(kind, seq_len)
                if self.kv_quant:
                    from repro.models import kvquant
                    return {
                        "k": kvquant.init_quant_cache(
                            batch_size, L, cfg.n_kv_heads, cfg.head_dim),
                        "v": kvquant.init_quant_cache(
                            batch_size, L, cfg.n_kv_heads, cfg.head_dim)}
                shape = (batch_size, L, cfg.n_kv_heads, cfg.head_dim)
                return {"k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype)}
            if kind == RGLRU:
                return rglru.rglru_init_state(cfg, batch_size, dtype)
            if kind == RWKV:
                return rwkv6.rwkv_init_state(cfg, batch_size, dtype)
            raise ValueError(kind)

        def stack(kind):
            leaf = one(kind)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_blocks,) + a.shape),
                leaf)

        cache: Dict[str, Any] = {
            "blocks": [stack(kind) for kind in pattern] if self.n_blocks
            else [],
            "tail": [one(kind) for kind in self._tail(swa_variant)],
        }
        if cfg.is_encoder_decoder:
            shape = (batch_size, cfg.encoder_seq, cfg.n_kv_heads,
                     cfg.head_dim)
            cache["enc_kv"] = {
                "k": jnp.zeros((cfg.n_layers,) + shape, dtype),
                "v": jnp.zeros((cfg.n_layers,) + shape, dtype)}
        return cache

    def _pattern(self, swa_variant: bool):
        if swa_variant:
            return tuple(LOCAL if k == GLOBAL else k
                         for k in self.cfg.layer_pattern)
        return self.cfg.layer_pattern

    def _tail(self, swa_variant: bool):
        if swa_variant:
            return tuple(LOCAL if k == GLOBAL else k for k in self.tail_kinds)
        return self.tail_kinds

    # --------------------------- prefill ------------------------------
    def prefill(self, params, batch, cache_len: Optional[int] = None,
                swa_variant: bool = False):
        """Forward over a prompt, returning last-token logits + filled cache."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        cache_len = cache_len or S
        positions = jnp.arange(S)[None, :]
        pattern = self._pattern(swa_variant)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self._encode(params, batch["frames"])

        cache = self.init_cache(B, cache_len, swa_variant)
        enc_layer_idx = [0]

        def layer(x, p, kind, cache_leaf):
            new_cache = cache_leaf
            h = layers.rmsnorm(x, p["norm1"])
            if kind in (GLOBAL, LOCAL):
                q, k, v = attention.project_qkv(p["attn"], h, cfg)
                if self.use_rope:
                    q = layers.apply_rope(q, positions, cfg.rope_theta)
                    k = layers.apply_rope(k, positions, cfg.rope_theta)
                window = cfg.window if kind == LOCAL else None
                o = attention.chunked_attention(
                    q, k, v, causal=True, window=window,
                    pallas_fn=self.pallas_fn)
                x = x + o.reshape(B, S, -1) @ p["attn"]["wo"]
                # fill ring cache with the trailing L positions
                if self.kv_quant:
                    from repro.models import kvquant
                    L = cache_leaf["k"]["q"].shape[1]
                    take = min(L, S)
                    slots = jnp.mod(jnp.arange(S - take, S), L)
                    new_cache = {}
                    for name, val in (("k", k), ("v", v)):
                        qv, sv = kvquant.quantize_kv(val[:, -take:])
                        new_cache[name] = {
                            "q": cache_leaf[name]["q"].at[:, slots].set(qv),
                            "scale": cache_leaf[name]["scale"]
                            .at[:, slots].set(sv)}
                else:
                    L = cache_leaf["k"].shape[1]
                    take = min(L, S)
                    slots = jnp.mod(jnp.arange(S - take, S), L)
                    new_cache = {
                        "k": cache_leaf["k"].at[:, slots].set(k[:, -take:]),
                        "v": cache_leaf["v"].at[:, slots].set(v[:, -take:])}
            elif kind == RGLRU:
                y, new_cache = rglru.rglru_apply(p["rglru"], h, cfg,
                                                 state=cache_leaf)
                x = x + y
            elif kind == RWKV:
                y, new_cache = rwkv6.rwkv_apply(p["rwkv"], h, cfg,
                                                state=cache_leaf)
                x = x + y
            if enc_out is not None:
                h = layers.rmsnorm(x, p["norm_x"])
                q, _, _ = attention.project_qkv(p["xattn"], h, cfg)
                _, ek, ev = attention.project_qkv(p["xattn"], enc_out, cfg)
                o = attention.chunked_attention(q, ek, ev, causal=False)
                x = x + o.reshape(B, S, -1) @ p["xattn"]["wo"]
                new_cache = (new_cache, {"k": ek, "v": ev})
            h = layers.rmsnorm(x, p["norm2"])
            if "moe" in p:
                y, _ = moe.moe_apply(p["moe"], h, cfg)
            else:
                y = layers.mlp_apply(p["mlp"], h, cfg.mlp)
            return x + y, new_cache

        def block_body(x, xs):
            block_params, block_cache = xs
            new_cache = []
            for j, kind in enumerate(pattern):
                x, nc = layer(x, block_params[j], kind, block_cache[j])
                new_cache.append(nc)
            return x, tuple(new_cache)

        fn = jax.checkpoint(block_body, policy=self.remat_policy) \
            if self.remat else block_body
        enc_caches = []
        if self.n_blocks > 0:
            x, new_blocks = jax.lax.scan(
                fn, x, (tuple(params["blocks"]), tuple(cache["blocks"])))
            if cfg.is_encoder_decoder:
                new_blocks, enc_b = _split_enc(new_blocks)
                enc_caches.append(enc_b)
            cache["blocks"] = list(new_blocks)
        for i, (p, kind) in enumerate(zip(params["tail"],
                                          self._tail(swa_variant))):
            x, nc = layer(x, p, kind, cache["tail"][i])
            if cfg.is_encoder_decoder:
                nc, enc_t = nc
                enc_caches.append(jax.tree.map(lambda a: a[None], enc_t))
            cache["tail"][i] = nc
        if cfg.is_encoder_decoder and enc_caches:
            cache["enc_kv"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *enc_caches) \
                if len(enc_caches) > 1 else enc_caches[0]
        x = layers.rmsnorm(x[:, -1:], params["final_norm"])
        logits = layers.unembed(params["unembed"], x)
        return logits, cache

    # --------------------------- decode -------------------------------
    def decode_step(self, params, token, cache, pos, swa_variant=False):
        """token: (B, 1) int32; pos: scalar int32 position of this token,
        or (B,) per-request positions (continuous batching)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], token)
        B = x.shape[0]
        pos = jnp.asarray(pos)
        if cfg.is_encoder_decoder:
            pe = layers.sinusoidal_position_at(pos, cfg.d_model)
            pe = pe[:, None, :] if pos.ndim == 1 else pe
            x = x + pe.astype(x.dtype)
        positions = pos.reshape(B, 1) if pos.ndim == 1 \
            else jnp.full((B, 1), pos)
        pattern = self._pattern(swa_variant)

        def layer(x, p, kind, cache_leaf, enc_kv=None):
            h = layers.rmsnorm(x, p["norm1"])
            if kind in (GLOBAL, LOCAL):
                q, k, v = attention.project_qkv(p["attn"], h, cfg)
                if self.use_rope:
                    q = layers.apply_rope(q, positions, cfg.rope_theta)
                    k = layers.apply_rope(k, positions, cfg.rope_theta)
                window = cfg.window if kind == LOCAL else None
                if self.kv_quant:
                    from repro.models import kvquant
                    kc = kvquant.quant_cache_update(cache_leaf["k"], k, pos)
                    vc = kvquant.quant_cache_update(cache_leaf["v"], v, pos)
                    o = attention.decode_attention_quant(q, kc, vc, pos,
                                                         window=window)
                else:
                    kc, vc = attention.cache_update(
                        cache_leaf["k"], cache_leaf["v"], k, v, pos)
                    o = attention.decode_attention(q, kc, vc, pos,
                                                   window=window)
                x = x + o.reshape(B, 1, -1) @ p["attn"]["wo"]
                new_cache = {"k": kc, "v": vc}
            elif kind == RGLRU:
                y, new_cache = rglru.rglru_decode_step(p["rglru"], h, cfg,
                                                       cache_leaf)
                x = x + y
            elif kind == RWKV:
                y, new_cache = rwkv6.rwkv_decode_step(p["rwkv"], h, cfg,
                                                      cache_leaf)
                x = x + y
            if enc_kv is not None:
                h = layers.rmsnorm(x, p["norm_x"])
                q, _, _ = attention.project_qkv(p["xattn"], h, cfg)
                o = attention.decode_attention(q, enc_kv["k"], enc_kv["v"],
                                               enc_kv["k"].shape[1] - 1)
                x = x + o.reshape(B, 1, -1) @ p["xattn"]["wo"]
            h = layers.rmsnorm(x, p["norm2"])
            if "moe" in p:
                y, _ = moe.moe_apply(p["moe"], h, cfg)
            else:
                y = layers.mlp_apply(p["mlp"], h, cfg.mlp)
            return x + y, new_cache

        P = len(pattern)
        enc_kv = cache.get("enc_kv")

        def block_body(carry, xs):
            x, li = carry
            if enc_kv is None:
                block_params, block_cache = xs
                enc_slices = [None] * P
            else:
                block_params, block_cache, enc_slices = xs
            new_cache = []
            for j, kind in enumerate(pattern):
                es = enc_slices[j] if enc_kv is not None else None
                x, nc = layer(x, block_params[j], kind, block_cache[j], es)
                new_cache.append(nc)
            return (x, li + P), tuple(new_cache)

        if self.n_blocks > 0:
            xs = (tuple(params["blocks"]), tuple(cache["blocks"]))
            if enc_kv is not None:
                # reshape (n_layers, ...) -> per-pattern-position slices
                nb = self.n_blocks
                sliced = jax.tree.map(
                    lambda a: a[:nb * P].reshape(nb, P, *a.shape[1:]),
                    enc_kv)
                xs = xs + ([jax.tree.map(lambda a: a[:, j], sliced)
                            for j in range(P)],)
            (x, _), new_blocks = jax.lax.scan(block_body, (x, 0), xs)
            cache["blocks"] = list(new_blocks)
        for i, (p, kind) in enumerate(zip(params["tail"],
                                          self._tail(swa_variant))):
            es = None
            if enc_kv is not None:
                es = jax.tree.map(lambda a: a[self.n_blocks * P + i], enc_kv)
            x, nc = layer(x, p, kind, cache["tail"][i], es)
            cache["tail"][i] = nc
        x = layers.rmsnorm(x, params["final_norm"])
        logits = layers.unembed(params["unembed"], x)
        return logits, cache


def _split_enc(new_blocks):
    """Separate (cache, enc_kv) tuples produced inside the prefill scan."""
    caches = tuple(nc[0] for nc in new_blocks)
    encs = tuple(nc[1] for nc in new_blocks)
    # encs: per pattern position, stacked over blocks -> (n_layers, ...)
    enc = jax.tree.map(
        lambda *xs: jnp.stack(xs, axis=1).reshape(-1, *xs[0].shape[1:]), *encs)
    return caches, enc


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)
